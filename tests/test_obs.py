"""Observability layer (PR: metrics registry + trace spans + telemetry).

Registry level: counter/gauge/histogram semantics, label sets, quantile
estimates, snapshot schema, Prometheus exposition, NullRegistry no-ops.
Trace level: span reconstruction, JSONL round-trip, lifecycle
validation failure modes.  Engine level: a FakeRunner advances a
``VirtualClock`` by known per-call costs, so ``phase_s``, decode gaps,
the new histograms and ``Completion.t_sched`` are asserted against
hand-computed stamps — admission, chunked prefill and preemption
included.  End-to-end: the real tiny model through the continuous and
async drivers must emit a valid snapshot and a valid trace.
"""

import json

import jax
import jax.numpy as jnp
import pytest

from repro.models import ModelConfig, build_model
from repro.obs import (MetricsRegistry, NullRegistry, NullTracer,
                       RequestTracer, load_jsonl, reconstruct_spans,
                       validate_events, validate_snapshot)
from repro.obs.trace import TraceEvent
from repro.obs.validate import require_gauge
from repro.serving import (AsyncEngine, ContinuousServingEngine,
                           EngineCore, Request, RequestState,
                           SamplingParams, ServingEngine, VirtualClock,
                           throughput_report)


@pytest.fixture(scope="module")
def tiny():
    cfg = ModelConfig(name="tiny", arch_type="dense", n_layers=2,
                      d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
                      vocab_size=259, dtype=jnp.float32)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


# ---------------------------------------------------------------------
# metrics registry
# ---------------------------------------------------------------------
def test_counter_gauge_basics():
    reg = MetricsRegistry()
    c = reg.counter("x.count", "help")
    c.inc()
    c.inc(2.5)
    assert c.value() == 3.5
    g = reg.gauge("x.level")
    g.set(7)
    g.set(3)
    assert g.value() == 3.0
    # get-or-create is idempotent; kind mismatch raises
    assert reg.counter("x.count") is c
    with pytest.raises(ValueError):
        reg.gauge("x.count")


def test_labels_are_independent_series():
    reg = MetricsRegistry()
    g = reg.gauge("pool.free")
    b0 = g.labels(node=0, shard=1)
    b1 = g.labels(node=1, shard=1)
    b0.set(5)
    b1.set(9)
    assert g.value(node=0, shard=1) == 5.0
    assert g.value(node=1, shard=1) == 9.0
    # label order does not matter
    assert g.value(shard=1, node=0) == 5.0


def test_histogram_counts_and_quantiles():
    reg = MetricsRegistry()
    h = reg.histogram("lat_ms", buckets=(1.0, 10.0, 100.0))
    b = h.labels()
    for v in (0.5, 2.0, 3.0, 50.0, 500.0):
        b.observe(v)
    s, n = h.value()
    assert n == 5 and s == pytest.approx(555.5)
    # ranks: bucket<=1 has 1, (1,10] has 2, (10,100] has 1, +Inf 1
    assert 0.0 < h.quantile(0.1) <= 1.0
    assert 1.0 < h.quantile(0.5) <= 10.0
    assert h.quantile(0.999) == 100.0       # overflow clamps to top bound
    assert reg.histogram("empty").quantile(0.5) == 0.0


def test_snapshot_schema_and_reset():
    reg = MetricsRegistry()
    reg.counter("a.b").inc(2)
    reg.gauge("c.d").set(1, node=0)
    reg.histogram("e.f").observe(3.0)
    snap = reg.snapshot()
    assert validate_snapshot(snap) == []
    assert json.loads(reg.snapshot_json()) == json.loads(
        json.dumps(snap))   # round-trips through JSON
    names = [c["name"] for c in snap["counters"]]
    assert names == ["a.b"]
    assert snap["gauges"][0]["labels"] == {"node": "0"}
    h = snap["histograms"][0]
    assert sum(h["counts"]) == h["count"] == 1
    reg.reset()
    assert reg.snapshot()["counters"] == []


def test_snapshot_validation_failure_modes():
    assert validate_snapshot([]) != []                  # not an object
    assert any("version" in p for p in validate_snapshot(
        {"version": 99, "counters": [], "gauges": [], "histograms": []}))
    bad_hist = {"version": 1, "counters": [], "gauges": [],
                "histograms": [{"name": "h", "labels": {},
                                "buckets": [1.0], "counts": [1, 0, 0],
                                "sum": 1.0, "count": 1}]}
    assert any("buckets" in p for p in validate_snapshot(bad_hist))


def test_prometheus_exposition():
    reg = MetricsRegistry()
    reg.counter("serving.steps", "engine steps").inc(4)
    h = reg.histogram("d.ms", buckets=(1.0, 10.0))
    h.observe(0.5)
    h.observe(5.0)
    text = reg.to_prometheus()
    assert "# TYPE serving_steps counter" in text
    assert "serving_steps 4" in text
    assert '# HELP serving_steps engine steps' in text
    assert 'd_ms_bucket{le="1"} 1' in text      # cumulative
    assert 'd_ms_bucket{le="10"} 2' in text
    assert 'd_ms_bucket{le="+Inf"} 2' in text
    assert "d_ms_count 2" in text


def test_null_registry_is_inert():
    reg = NullRegistry()
    c = reg.counter("x")
    c.inc(5)
    b = c.labels(node=0)
    b.inc()
    assert c.value() == 0.0
    assert reg.snapshot() == {"version": 1, "counters": [], "gauges": [],
                              "histograms": []}
    assert validate_snapshot(reg.snapshot()) == []


def test_require_gauge():
    reg = MetricsRegistry()
    reg.gauge("kv_pool.pages_free").set(3, node=0, shard=1)
    snap = reg.snapshot()
    assert require_gauge(snap, "kv_pool.pages_free",
                         ["node", "shard"]) == []
    assert require_gauge(snap, "kv_pool.pages_free",
                         ["node", "shard", "rack"]) != []
    assert require_gauge(snap, "nope", []) != []


# ---------------------------------------------------------------------
# trace
# ---------------------------------------------------------------------
def _ev(uid, name, t, **attrs):
    return TraceEvent((uid, name, t, attrs))


def test_span_reconstruction():
    evs = [_ev(1, "QUEUED", 0.0), _ev(1, "PREFILLING", 1.0),
           _ev(1, "PREFILL_CHUNK", 1.0, start=0, n=8),
           _ev(1, "DECODING", 2.0), _ev(1, "FINISHED", 5.0)]
    spans = reconstruct_spans(evs)[1]
    assert spans == [("QUEUED", 0.0, 1.0), ("PREFILLING", 1.0, 2.0),
                     ("DECODING", 2.0, 5.0), ("FINISHED", 5.0, 5.0)]
    assert validate_events(evs) == []


def test_validate_events_failure_modes():
    # non-monotone stamps
    assert any("non-monotone" in p for p in validate_events(
        [_ev(1, "QUEUED", 2.0), _ev(1, "FINISHED", 1.0)]))
    # lifecycle must start at QUEUED
    assert any("starts at" in p for p in validate_events(
        [_ev(1, "DECODING", 0.0), _ev(1, "FINISHED", 1.0)]))
    # nothing after a terminal event
    assert any("after terminal" in p for p in validate_events(
        [_ev(1, "QUEUED", 0.0), _ev(1, "CANCELLED", 1.0),
         _ev(1, "DECODING", 2.0)]))
    # terminal required (unless disabled)
    evs = [_ev(1, "QUEUED", 0.0)]
    assert any("no terminal" in p for p in validate_events(evs))
    assert validate_events(evs, require_terminal=False) == []
    # unknown event name
    assert any("unknown event" in p for p in validate_events(
        [_ev(1, "QUEUED", 0.0), _ev(1, "WAT", 1.0),
         _ev(1, "FINISHED", 2.0)]))


def test_trace_jsonl_round_trip(tmp_path):
    tr = RequestTracer()
    tr.event(3, "QUEUED", 0.25, prompt_len=9)
    tr.event(3, "FINISHED", 1.5, n_tokens=4)
    path = str(tmp_path / "trace.jsonl")
    assert tr.write_jsonl(path) == 2
    back = load_jsonl(path)
    assert back == tr.events()
    assert back[0].attrs == {"prompt_len": 9}
    assert NullTracer().enabled is False


# ---------------------------------------------------------------------
# engine time accounting under VirtualClock (FakeRunner advances the
# clock by known per-call costs, so every stamp is hand-computable)
# ---------------------------------------------------------------------
PREFILL_COST = 0.005
DECODE_COST = 0.002


class FakeRunner:
    """Stands in for ModelRunner: each device call advances the
    VirtualClock by a fixed known cost and returns zero logits (greedy
    -> token 0)."""

    def __init__(self, core, clock):
        self.max_pages = core.runner.max_pages
        self._V = core.model.cfg.vocab_size
        self._B = core.max_running
        self.clock = clock

    def set_block_tables(self, bt):
        pass

    def apply_copy_rows(self, src, dst):
        pass

    def prefill_chunk(self, tokens, *, slot, start, fresh):
        self.clock.advance(PREFILL_COST)
        return jnp.zeros((1, 1, self._V), jnp.float32)

    def decode(self, fed, pos):
        self.clock.advance(DECODE_COST)
        return jnp.zeros((self._B, 1, self._V), jnp.float32)


def _fake_core(tiny, clock, tracer=None, registry=None, **kw):
    _cfg, model, params = tiny
    core = EngineCore(model, params, clock=clock, tracer=tracer,
                      registry=registry, **kw)
    core.runner = FakeRunner(core, clock)
    return core


def _drain(core, clock):
    done = []
    for _ in range(500):
        if not core.has_work():
            break
        done.extend(core.step(clock.now()).finished)
    assert not core.has_work()
    return sorted(done, key=lambda c: c.uid)


def test_phase_and_gap_accounting_matches_hand_stamps(tiny):
    clock = VirtualClock()
    tracer = RequestTracer()
    core = _fake_core(tiny, clock, tracer=tracer, max_len=64,
                      max_running=2, page_size=8, prefix_cache=False)
    sp = SamplingParams(max_new_tokens=3)
    for uid, plen in ((0, 10), (1, 6)):
        core.submit(Request(uid=uid, prompt=list(range(1, plen + 1)),
                            sampling=sp))
    comps = _drain(core, clock)

    # step 1: two one-shot prefills (0.005 each, first token sampled);
    # steps 2-3: batched decodes (0.002 each) -> 3 tokens, done
    assert core.phase_s["prefill_s"] == pytest.approx(2 * PREFILL_COST)
    assert core.phase_s["decode_s"] == pytest.approx(2 * DECODE_COST)
    assert core.decode_gaps_s == pytest.approx([DECODE_COST])

    reg = core.registry
    s, n = reg.histogram("serving.decode.itl_ms").value()
    assert (n, s) == (1, pytest.approx(DECODE_COST * 1e3))
    s, n = reg.histogram("serving.prefill.chunk_ms").value()
    assert (n, s) == (2, pytest.approx(2 * PREFILL_COST * 1e3))
    assert reg.counter("serving.tokens.prefill").value() == 16
    assert reg.counter("serving.tokens.decode").value() == 4
    assert reg.counter("scheduler.admissions").value() == 2

    # hand-computed completion stamps: A prefills [0, 0.005],
    # B [0.005, 0.010]; decodes end at 0.012 and 0.014
    a, b = comps
    assert (a.t0, b.t0) == (0.0, 0.0)
    assert a.t_first == pytest.approx(PREFILL_COST)
    assert b.t_first == pytest.approx(2 * PREFILL_COST)
    assert a.t1 == b.t1 == pytest.approx(0.014)
    assert a.t_sched == b.t_sched == 0.0    # admitted at submission
    assert validate_events(tracer.events()) == []

    # reset_run_stats clears the run-scoped series only
    core.reset_run_stats()
    assert core.phase_s == {"prefill_s": 0.0, "decode_s": 0.0}
    assert core.decode_gaps_s == []
    assert reg.histogram("serving.decode.itl_ms").value() == (0.0, 0)
    assert reg.counter("scheduler.admissions").value() == 2  # cumulative


def test_chunked_prefill_chunk_events_and_histogram(tiny):
    clock = VirtualClock()
    tracer = RequestTracer()
    core = _fake_core(tiny, clock, tracer=tracer, max_len=64,
                      max_running=2, page_size=8, prefill_chunk=8,
                      prefix_cache=False)
    core.submit(Request(uid=0, prompt=list(range(1, 21)),
                        sampling=SamplingParams(max_new_tokens=1)))
    _drain(core, clock)

    chunks = [e for e in tracer.events(0) if e.name == "PREFILL_CHUNK"]
    assert [(e.attrs["start"], e.attrs["n"]) for e in chunks] == [
        (0, 8), (8, 8), (16, 4)]
    assert [e.t for e in chunks] == pytest.approx(
        [0.0, PREFILL_COST, 2 * PREFILL_COST])
    assert core.phase_s["prefill_s"] == pytest.approx(3 * PREFILL_COST)
    s, n = core.registry.histogram("serving.prefill.chunk_ms").value()
    assert (n, s) == (3, pytest.approx(3 * PREFILL_COST * 1e3))
    names = [e.name for e in tracer.events(0)
             if e.name != "PREFILL_CHUNK"]
    assert names == ["QUEUED", "PREFILLING", "DECODING", "FINISHED"]


def test_preemption_trace_and_counter(tiny):
    # pool sized so two 8-token prompts admit but cannot both grow:
    # page_size 4, 7 usable pages; the youngest (uid 1) gets preempted,
    # requeues, and restarts after uid 0 finishes
    clock = VirtualClock()
    tracer = RequestTracer()
    core = _fake_core(tiny, clock, tracer=tracer, max_len=32,
                      max_running=2, page_size=4, n_pages=8,
                      prefix_cache=False)
    sp = SamplingParams(max_new_tokens=8)
    for uid in (0, 1):
        core.submit(Request(uid=uid, prompt=list(range(1, 9)),
                            sampling=sp))
    comps = _drain(core, clock)

    assert core.registry.counter("scheduler.preemptions").value() >= 1
    victims = {e.uid for e in tracer.events() if e.name == "PREEMPTED"}
    assert victims                          # somebody was preempted...
    for uid in victims:
        names = [e.name for e in tracer.events(uid)]
        i = names.index("PREEMPTED")
        assert "PREFILLING" in names[i:]    # ...and recompute-restarted
        assert names[-1] == "FINISHED"
    assert validate_events(tracer.events()) == []
    assert [len(c.tokens) for c in comps] == [8, 8]


def test_t_sched_decomposes_ttft(tiny):
    # max_running=1 serialises admissions: uid 1 waits for uid 0
    clock = VirtualClock()
    core = _fake_core(tiny, clock, max_len=64, max_running=1,
                      page_size=8, prefix_cache=False)
    sp = SamplingParams(max_new_tokens=3)
    for uid in (0, 1):
        core.submit(Request(uid=uid, prompt=list(range(1, 9)),
                            sampling=sp))
    comps = _drain(core, clock)

    a, b = comps
    assert a.t_sched == 0.0
    # uid 0 runs prefill (0.005) + 2 decodes (0.004) -> finishes (and
    # frees its slot) at 0.009; uid 1 admits on that same step
    assert b.t_sched == pytest.approx(0.009)
    assert b.t_first == pytest.approx(b.t_sched + PREFILL_COST)
    queue_wait = b.t_sched - b.t0
    prefill_wait = b.t_first - b.t_sched
    assert queue_wait + prefill_wait == pytest.approx(b.t_first - b.t0)


def test_cancel_emits_cancelled_event(tiny):
    clock = VirtualClock()
    tracer = RequestTracer()
    core = _fake_core(tiny, clock, tracer=tracer, max_len=64,
                      max_running=2, page_size=8, prefill_chunk=4,
                      prefix_cache=False)
    seq = core.submit(Request(uid=0, prompt=list(range(1, 17)),
                              sampling=SamplingParams(max_new_tokens=4)))
    core.step(0.0)                          # mid-prefill
    assert core.cancel(seq)
    assert [e.name for e in tracer.events(0)] == [
        "QUEUED", "PREFILLING", "PREFILL_CHUNK", "CANCELLED"]
    assert validate_events(tracer.events()) == []
    assert not core.has_work()


def test_pool_gauges_sampled_per_step(tiny):
    clock = VirtualClock()
    core = _fake_core(tiny, clock, max_len=64, max_running=2,
                      page_size=8, n_nodes=2, prefix_cache=False)
    core.submit(Request(uid=0, prompt=list(range(1, 9)),
                        sampling=SamplingParams(max_new_tokens=2)))
    _drain(core, clock)
    snap = core.registry.snapshot()
    assert require_gauge(snap, "kv_pool.pages_free",
                         ["node", "shard"]) == []
    free = {(g["labels"]["node"], g["labels"]["shard"]): g["value"]
            for g in snap["gauges"] if g["name"] == "kv_pool.pages_free"}
    assert set(free) == {("0", "0"), ("1", "0")}
    assert sum(free.values()) == core.pool.n_free() - core.pool.n_retained()


def test_null_registry_disables_engine_metrics(tiny):
    clock = VirtualClock()
    core = _fake_core(tiny, clock, registry=NullRegistry(), max_len=64,
                      max_running=2, page_size=8, prefix_cache=False)
    core.submit(Request(uid=0, prompt=list(range(1, 9)),
                        sampling=SamplingParams(max_new_tokens=2)))
    comps = _drain(core, clock)
    assert len(comps[0].tokens) == 2        # serving still works
    assert core.phase_s == {"prefill_s": 0.0, "decode_s": 0.0}
    assert core.registry.snapshot()["counters"] == []


# ---------------------------------------------------------------------
# throughput_report zero-duration phases (satellite fix)
# ---------------------------------------------------------------------
def test_throughput_report_zero_phases():
    from repro.serving.engine import Completion
    comps = [Completion(uid=0, prompt_len=4, tokens=[1, 2],
                        latency_s=0.0, prefill_s=0.0)]
    rep = throughput_report(comps, wall_s=0.0, prefill_s=0.0,
                            decode_s=0.0)
    assert rep["decode_tok_per_s"] == 0.0   # explicit, not astronomical
    assert rep["prefill_tok_per_s"] == 0.0
    rep = throughput_report(comps, wall_s=2.0, prefill_s=0.5,
                            decode_s=1.5)
    assert rep["decode_tok_per_s"] == pytest.approx(2 / 1.5)


# ---------------------------------------------------------------------
# end-to-end: real model through the drivers
# ---------------------------------------------------------------------
def test_continuous_engine_end_to_end_obs(tiny):
    _cfg, model, params = tiny
    tracer = RequestTracer()
    eng = ContinuousServingEngine(model, params, max_len=64,
                                  max_running=4, page_size=8,
                                  prefill_chunk=8, clock=VirtualClock(),
                                  tracer=tracer)
    reqs = [Request(uid=i, prompt=list(range(1, 12 + i)),
                    sampling=SamplingParams(max_new_tokens=4))
            for i in range(3)]
    comps = eng.generate(reqs, arrivals=[0.0, 0.01, 0.02])
    assert [len(c.tokens) for c in comps] == [4, 4, 4]
    assert validate_snapshot(eng.registry.snapshot()) == []
    assert validate_events(tracer.events()) == []
    for c in comps:
        assert c.t0 <= c.t_sched <= c.t_first <= c.t1
        spans = tracer.spans(c.uid)
        assert [s[0] for s in spans] == ["QUEUED", "PREFILLING",
                                         "DECODING", "FINISHED"]
    text = eng.registry.to_prometheus()
    assert "serving_decode_itl_ms_bucket" in text
    assert "kv_pool_pages_free" in text


def test_bucket_engine_stamps_t_sched(tiny):
    _cfg, model, params = tiny
    eng = ServingEngine(model, params, max_len=32)
    comps = eng.generate(
        [Request(uid=i, prompt=[1, 2, 3, 4],
                 sampling=SamplingParams(max_new_tokens=2))
         for i in range(2)], max_batch=2)
    for c in comps:
        assert c.t_sched == c.t0            # instant admission
    rep = throughput_report(comps, **eng.last_phase_s)
    assert rep["new_tokens"] == 4


@pytest.mark.slow
def test_async_engine_failure_and_obs(tiny):
    _cfg, model, params = tiny
    tracer = RequestTracer()
    eng = AsyncEngine(model, params, max_len=32, max_running=2,
                      page_size=8, tracer=tracer)
    try:
        ok = eng.submit(Request(uid=0, prompt=[1, 2, 3],
                                sampling=SamplingParams(
                                    max_new_tokens=2)))
        bad = eng.submit(Request(uid=1, prompt=list(range(1, 64)),
                                 sampling=SamplingParams(
                                     max_new_tokens=2)))
        comp = eng.result(ok, timeout=120)
        assert len(comp.tokens) == 2 and comp.t_sched >= comp.t0
        with pytest.raises(Exception):
            eng.result(bad, timeout=120)
        assert bad.state is RequestState.FAILED
    finally:
        eng.shutdown()
    assert eng.registry.counter("async.submitted").value() == 2
    assert eng.registry.counter("async.failed").value() == 1
    assert validate_events(tracer.events()) == []
    names = {e.name for e in tracer.events()}
    assert "FAILED" in names and "FINISHED" in names
