"""Thread manager (§2.4) + Sync A/B (§3.4) + NUMA cost model (§3.1, §4)."""

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.numa import (KUNPENG_920_4NODE, QWEN3_4B,
                             async_gain_tokens_per_s, decode_throughput,
                             fig10_single_node, fig11_multi_node,
                             fig12_13_long_prompt, headline_gain)
from repro.core.threads import SyncSchedule, ThreadPool


class TestThreadPool:
    def test_distribute_binding(self):
        pool = ThreadPool(8, n_nodes=4, binding="distribute")
        assert pool.affinity == [0, 1, 2, 3, 0, 1, 2, 3]

    def test_isolate_binding_packs(self):
        pool = ThreadPool(8, n_nodes=4, binding="isolate")
        assert pool.affinity == [0, 0, 1, 1, 2, 2, 3, 3]

    def test_split_by_node_and_merge(self):
        pool = ThreadPool(8, n_nodes=4)
        groups = pool.split(4)
        assert [g.node_id for g in groups] == [0, 1, 2, 3]
        assert all(len(g) == 2 for g in groups)
        g = pool.merge()
        assert pool.n_groups == 1 and len(g) == 8

    def test_group_of(self):
        pool = ThreadPool(6, n_nodes=2)
        pool.split(2)
        assert pool.group_of(0).group_id != pool.group_of(1).group_id


class TestSyncSchedules:
    @given(st.lists(st.lists(st.floats(0.01, 10.0), min_size=2, max_size=8),
                    min_size=2, max_size=6).filter(
                        lambda d: len({len(r) for r in d}) == 1))
    @settings(max_examples=60, deadline=None)
    def test_async_never_slower(self, durations):
        """max-of-sums <= sum-of-maxes: Sync B always wins (Fig 9)."""
        a = SyncSchedule.sync_a(durations)
        b = SyncSchedule.sync_b(durations)
        assert b.makespan <= a.makespan + 1e-9
        assert b.global_barriers == 2
        assert a.global_barriers == len(durations[0])

    def test_skewed_groups_show_gain(self):
        # one slow group per op, alternating -> big idle under Sync A
        d = [[2.0, 0.5], [0.5, 2.0]]
        assert SyncSchedule.speedup(d) == pytest.approx(4.0 / 2.5)

    def test_uniform_no_gain(self):
        d = [[1.0, 1.0], [1.0, 1.0]]
        assert SyncSchedule.speedup(d) == pytest.approx(1.0)


class TestNumaCostModel:
    """The cost model must reproduce the paper's measured claims."""

    def test_table1_bandwidth_matrix(self):
        m = KUNPENG_920_4NODE.bandwidth_matrix()
        assert m.shape == (4, 4)
        assert np.all(np.diag(m) >= 100)              # local ~102 GB/s
        off = m[~np.eye(4, dtype=bool)]
        assert np.all((off >= 20) & (off <= 30))      # remote 22-26 GB/s
        # ~4x local:remote gap (paper §3.1)
        assert 3.5 <= np.diag(m).mean() / off.mean() <= 5.0

    def test_headline_46_percent(self):
        """'up to 46% higher inference throughput' at 4 nodes."""
        g = headline_gain()
        assert 0.40 <= g <= 0.52, g

    def test_async_gain_about_5_toks(self):
        """§3.4: asynchronous subgraphs contribute ≈ +5 tok/s."""
        g = async_gain_tokens_per_s()
        assert 2.0 <= g <= 8.0, g

    def test_fig10_single_node_scaling_saturates(self):
        f = fig10_single_node()
        arc = f["arclight"]
        assert arc[1] > arc[0] * 1.5          # scales at low threads
        assert abs(arc[-1] - arc[-2]) < 0.2 * arc[-1]  # saturates
        # ArcLight slightly above llama.cpp on one node (Fig 10)
        assert f["arclight"][-1] > f["llama.cpp"][-1]

    def test_fig11_tp_beats_distribute(self):
        f = fig11_multi_node()
        for n in (2, 4):
            assert f["arclight_tp"][n][-1] > f["llama.cpp"][n][-1]
        # gain grows with node count ("up to")
        gain2 = f["arclight_tp"][2][-1] / f["llama.cpp"][2][-1]
        gain4 = f["arclight_tp"][4][-1] / f["llama.cpp"][4][-1]
        assert gain4 > gain2
        # sync B > sync A everywhere TP is on
        assert all(b >= a for b, a in
                   zip(f["arclight_tp"][4], f["arclight_tp_sync_a"][4]))

    def test_fig12_13_prefill_gain_less_than_decode(self):
        """A.2: TP helps decode (bandwidth-bound) more than prefill
        (compute-bound)."""
        f = fig12_13_long_prompt()
        decode_gain = (f["decode"]["arclight_tp"][4]
                       / f["decode"]["llama.cpp"][4])
        prefill_gain = (f["prefill"]["arclight_tp"][4]
                        / f["prefill"]["llama.cpp"][4])
        assert decode_gain > prefill_gain
        assert prefill_gain >= 0.99           # never a regression

    def test_remote_bytes_eliminated_by_tp(self):
        llama = decode_throughput(QWEN3_4B, KUNPENG_920_4NODE, 192, 4,
                                  "llama_uma_distribute")
        arc = decode_throughput(QWEN3_4B, KUNPENG_920_4NODE, 192, 4,
                                "arclight_numa_tp")
        assert arc.remote_bytes < 0.02 * llama.remote_bytes

    @given(st.integers(6, 48), st.sampled_from([1, 2, 4]))
    @settings(max_examples=30, deadline=None)
    def test_tp_never_loses_to_distribute(self, tpn, nodes):
        t = tpn * nodes
        a = decode_throughput(QWEN3_4B, KUNPENG_920_4NODE, t, nodes,
                              "arclight_numa_tp" if nodes > 1
                              else "arclight_single")
        b = decode_throughput(QWEN3_4B, KUNPENG_920_4NODE, t, nodes,
                              "llama_uma_distribute" if nodes > 1
                              else "llama_uma_isolate")
        assert a.tokens_per_s >= b.tokens_per_s * 0.98
