"""Memory manager (paper §2.3): pools, double buffering — property tests."""

import numpy as np
from _hypothesis_compat import given, settings, st

from repro.core.memory import MemoryManager, Pool, _align
from repro.core.tensor import OpType, make_header


def _acts(sizes_per_layer):
    """Build activation headers per layer from a list of lists of sizes."""
    layers = []
    for i, sizes in enumerate(sizes_per_layer):
        layers.append([make_header((s,), np.float32, op=OpType.ADD,
                                   name=f"l{i}a{j}")
                       for j, s in enumerate(sizes)])
    return layers


class TestPool:
    def test_alignment(self):
        p = Pool("p", 0)
        a = p.alloc("x", 130 * 4)
        assert a.nbytes % 128 == 0
        b = p.alloc("y", 4)
        assert b.offset == a.nbytes

    @given(st.lists(st.integers(1, 10_000), min_size=1, max_size=50))
    @settings(max_examples=50, deadline=None)
    def test_no_overlap(self, sizes):
        p = Pool("p", 0)
        allocs = [p.alloc(f"t{i}", s) for i, s in enumerate(sizes)]
        spans = sorted((a.offset, a.offset + a.nbytes) for a in allocs)
        for (s1, e1), (s2, e2) in zip(spans, spans[1:]):
            assert e1 <= s2


class TestDoubleBuffering:
    @given(st.lists(st.lists(st.integers(4, 4096), min_size=1, max_size=4),
                    min_size=2, max_size=12))
    @settings(max_examples=40, deadline=None)
    def test_peak_is_two_layer_max(self, sizes_per_layer):
        """Double buffering: peak == max over layer parities (Fig 4),
        always <= the linear (no-reuse) plan."""
        mm_db = MemoryManager(1, numa=False, double_buffer=True)
        mm_db.plan_activations(_acts(sizes_per_layer))
        mm_lin = MemoryManager(1, numa=False, double_buffer=False)
        mm_lin.plan_activations(_acts(sizes_per_layer))
        peak_db = sum(mm_db.activation_bytes().values())
        peak_lin = sum(mm_lin.activation_bytes().values())
        assert peak_db <= peak_lin
        # exact: each parity buffer holds the max layer footprint of
        # that parity
        for parity in (0, 1):
            expect = max((sum(_align(s * 4) for s in sizes)  # f32 bytes
                          for i, sizes in enumerate(sizes_per_layer)
                          if i % 2 == parity), default=0)
            got = mm_db.act_pools[0][parity].peak
            assert got == expect

    def test_parity_reuse_no_aliasing_within_window(self):
        """Layer i's buffer must not alias layer i+1's (different parity)."""
        mm = MemoryManager(1, numa=False, double_buffer=True)
        layers = _acts([[128], [128], [128]])
        plan = mm.plan_activations(layers)
        a0 = plan["l0a0"]
        a1 = plan["l1a0"]
        a2 = plan["l2a0"]
        assert a0.pool != a1.pool          # adjacent layers: distinct pools
        assert a0.pool == a2.pool          # parity reuse
        assert a0.offset == a2.offset

    def test_uma_vs_numa_same_totals(self):
        """NUMA split moves bytes to node pools but conserves totals."""
        headers = [make_header((256,), np.float32, op=OpType.WEIGHT,
                               name=f"w{i}", node_id=i % 4)
                   for i in range(8)]
        numa = MemoryManager(4, numa=True)
        uma = MemoryManager(4, numa=False)
        for h in headers:
            numa.place_weight(make_header(h.shape, h.dtype, op=OpType.WEIGHT,
                                          name=h.name, node_id=h.node_id))
            uma.place_weight(make_header(h.shape, h.dtype, op=OpType.WEIGHT,
                                         name=h.name))
        assert (sum(numa.weight_bytes().values())
                == sum(uma.weight_bytes().values()))
        assert len([v for v in numa.per_node_bytes().values() if v]) == 4
