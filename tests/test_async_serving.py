"""Layered serving stack (PR: ModelRunner / EngineCore / AsyncEngine).

Core level: step-by-step driving with a VirtualClock (no sleeps, no
threads), cancellation mid-prefill draining the pool.  Async level:
sync-vs-async greedy token parity, live cancellation, stepper-thread
exception propagation to ``poll``, ``shutdown()`` joining the thread,
and the per-request state machine.  Thread-heavy cases are ``slow``
(CI's tier1 lane runs ``-m "not slow"``).
"""

import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import ModelConfig, build_model
from repro.serving import (AsyncEngine, AsyncEngineError,
                           ContinuousServingEngine, EngineCore, Request,
                           RequestState, SamplingParams, ServingEngine,
                           VirtualClock)


@pytest.fixture(scope="module")
def tiny():
    cfg = ModelConfig(name="tiny", arch_type="dense", n_layers=2,
                      d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
                      vocab_size=259, dtype=jnp.float32)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


MIXED_PROMPTS = [[1, 2, 3, 4, 5], [7, 8, 9], [10, 11, 12, 13, 14, 15, 16],
                 [5, 4, 3], [9, 9, 2, 1]]


def _reqs(max_new=5):
    return [Request(uid=i, prompt=p,
                    sampling=SamplingParams(max_new_tokens=max_new))
            for i, p in enumerate(MIXED_PROMPTS)]


class TestEngineCore:
    def test_step_returns_emitted_tokens_and_finishes(self, tiny):
        _, model, params = tiny
        core = EngineCore(model, params, max_len=32, max_running=2,
                          page_size=4, clock=VirtualClock())
        seq = core.submit(Request(uid=0, prompt=[1, 2, 3],
                                  sampling=SamplingParams(
                                      max_new_tokens=3)))
        emitted, finished, steps = [], [], 0
        while core.has_work():
            res = core.step()
            emitted += [t for _, t in res.emitted]
            finished += res.finished
            steps += 1
            assert steps < 20
        assert len(finished) == 1 and finished[0].uid == 0
        assert finished[0].tokens == emitted == seq.generated
        assert core.pool.n_live() == 0

    def test_cancel_mid_prefill_frees_all_pages(self, tiny):
        """Deterministic mid-prefill cancel: chunked prefill leaves the
        prompt partially resident after one step; cancel must release
        every page reference and leave the pool clean."""
        _, model, params = tiny
        core = EngineCore(model, params, max_len=64, max_running=2,
                          page_size=4, prefill_chunk=4,
                          prefix_cache=False, clock=VirtualClock())
        seq = core.submit(Request(uid=0, prompt=list(range(1, 18)),
                                  sampling=SamplingParams(
                                      max_new_tokens=4)))
        core.step()
        assert seq.is_prefilling and seq.slot >= 0   # mid-prefill
        assert core.pool.n_live() > 0
        assert core.cancel(seq)
        assert seq.slot == -1
        assert core.pool.n_live() == 0
        assert core.pool.n_free() == core.pool.cfg.n_pages - 1
        assert not core.has_work()
        assert not core.cancel(seq)                  # second time: gone

    def test_cancel_queued_sequence(self, tiny):
        _, model, params = tiny
        core = EngineCore(model, params, max_len=32, max_running=1,
                          page_size=4, clock=VirtualClock())
        a = core.submit(Request(uid=0, prompt=[1, 2, 3]))
        b = core.submit(Request(uid=1, prompt=[4, 5, 6]))
        core.step()                                  # a admits, b waits
        assert b.slot == -1 and core.scheduler.waiting
        assert core.cancel(b)
        assert not core.scheduler.waiting
        assert core.cancel(a)
        assert core.pool.n_live() == 0

    def test_virtual_clock_idle_waits_cost_no_wall_time(self, tiny):
        """The old engine busy-slept real seconds between arrivals; the
        injected clock makes the same workload run at device speed."""
        _, model, params = tiny
        clock = VirtualClock()
        eng = ContinuousServingEngine(model, params, max_len=32,
                                      max_running=2, page_size=4,
                                      clock=clock)
        reqs = [Request(uid=i, prompt=[3 + i, 5, 7],
                        sampling=SamplingParams(max_new_tokens=4))
                for i in range(2)]
        t0 = time.perf_counter()
        comps = eng.generate(reqs, arrivals=[0.0, 30.0])
        wall = time.perf_counter() - t0
        assert len(comps) == 2 and all(len(c.tokens) == 4 for c in comps)
        assert clock.slept_s >= 29.0, "idle wait went through the clock"
        assert wall < 10.0, "virtual sleep must not cost wall time"


class TestAsyncEngine:
    @pytest.mark.slow
    def test_async_matches_sync_and_bucket_greedy_tokens(self, tiny):
        _, model, params = tiny
        reqs = _reqs()
        bc = ServingEngine(model, params, max_len=48).generate(
            reqs, max_batch=4)
        sc = ContinuousServingEngine(model, params, max_len=48,
                                     max_running=3,
                                     page_size=4).generate(reqs)
        with AsyncEngine(model, params, max_len=48, max_running=3,
                         page_size=4) as eng:
            handles = [eng.submit(r) for r in reqs]
            ac = [eng.result(h, timeout=120) for h in handles]
        assert [c.tokens for c in bc] == [c.tokens for c in sc]
        assert [c.tokens for c in sc] == [c.tokens for c in ac]

    @pytest.mark.slow
    def test_stream_delivers_every_token_incrementally(self, tiny):
        _, model, params = tiny
        req = Request(uid=0, prompt=[1, 2, 3, 4, 5],
                      sampling=SamplingParams(max_new_tokens=6))
        with AsyncEngine(model, params, max_len=32, max_running=2,
                         page_size=4) as eng:
            h = eng.submit(req)
            streamed = list(eng.stream(h, timeout=120))
            comp = eng.result(h, timeout=10)
        assert streamed == comp.tokens and len(streamed) == 6

    @pytest.mark.slow
    def test_states_progress_through_the_machine(self, tiny):
        _, model, params = tiny
        req = Request(uid=0, prompt=list(range(1, 14)),
                      sampling=SamplingParams(max_new_tokens=5))
        with AsyncEngine(model, params, max_len=32, max_running=2,
                         page_size=4, prefill_chunk=2) as eng:
            h = eng.submit(req)
            seen = {h.state}
            while True:
                res = eng.poll(h)
                seen.add(res.state)
                if res.done:
                    break
                time.sleep(0.005)
        assert res.state is RequestState.FINISHED
        assert res.completion is not None
        legal = {RequestState.QUEUED, RequestState.PREFILLING,
                 RequestState.DECODING, RequestState.FINISHED}
        assert seen <= legal and RequestState.FINISHED in seen

    @pytest.mark.slow
    def test_cancel_frees_pages_and_is_terminal(self, tiny):
        """Cancel a long chunked prefill while the stepper is live: the
        handle ends CANCELLED and the pool drains completely."""
        _, model, params = tiny
        long_req = Request(uid=0, prompt=list(range(1, 40)),
                           sampling=SamplingParams(max_new_tokens=50))
        with AsyncEngine(model, params, max_len=64, max_running=2,
                         page_size=4, prefill_chunk=2,
                         prefix_cache=False) as eng:
            h = eng.submit(long_req)
            deadline = time.perf_counter() + 60
            while eng.poll(h).state is RequestState.QUEUED:
                assert time.perf_counter() < deadline
                time.sleep(0.002)
            assert eng.cancel(h)
            while not eng.poll(h).done:
                assert time.perf_counter() < deadline
                time.sleep(0.002)
            assert eng.poll(h).state is RequestState.CANCELLED
            # stepper idle now: pool state is stable to assert on
            assert eng.core.pool.n_live() == 0
            assert (eng.core.pool.n_free()
                    == eng.core.pool.cfg.n_pages - 1)
            assert eng.core.pool.pending_copies == []
            assert not eng.cancel(h)                 # already terminal

    @pytest.mark.slow
    def test_stepper_exception_surfaces_on_poll(self, tiny):
        _, model, params = tiny
        eng = AsyncEngine(model, params, max_len=32, max_running=2,
                          page_size=4)
        boom = RuntimeError("injected stepper failure")

        def exploding_step(now=0.0):
            raise boom

        eng.core.step = exploding_step
        h = eng.submit(Request(uid=0, prompt=[1, 2, 3]))
        deadline = time.perf_counter() + 30
        while True:
            assert time.perf_counter() < deadline
            try:
                res = eng.poll(h)
            except AsyncEngineError as e:
                assert e.__cause__ is boom
                break
            assert not res.done
            time.sleep(0.002)
        assert h.state is RequestState.FAILED
        with pytest.raises(AsyncEngineError):      # submit fails too
            eng.submit(Request(uid=1, prompt=[1]))
        eng.shutdown()

    @pytest.mark.slow
    def test_oversized_prompt_fails_only_that_request(self, tiny):
        _, model, params = tiny
        with AsyncEngine(model, params, max_len=16, max_running=2,
                         page_size=4) as eng:
            bad = eng.submit(Request(uid=0, prompt=[1] * 17))
            good = eng.submit(Request(uid=1, prompt=[1, 2, 3],
                                      sampling=SamplingParams(
                                          max_new_tokens=3)))
            comp = eng.result(good, timeout=120)
            assert len(comp.tokens) == 3
            with pytest.raises(AsyncEngineError, match="failed"):
                eng.result(bad, timeout=10)
            assert bad.state is RequestState.FAILED
            # terminal handles leave the registry (no per-request leak)
            assert bad.uid not in eng._handles
            assert good.uid not in eng._handles

    @pytest.mark.slow
    def test_prompt_exceeding_page_budget_fails_only_that_request(
            self, tiny):
        """A prompt that fits max_len but not the pool's per-sequence
        page budget must fail its own handle at submit-validation, not
        raise inside scheduler.step and kill the stepper."""
        _, model, params = tiny
        with AsyncEngine(model, params, max_len=32, max_running=2,
                         page_size=4, n_pages=4) as eng:   # 3 usable
            bad = eng.submit(Request(uid=0, prompt=[1] * 14))  # 4 pages
            good = eng.submit(Request(uid=1, prompt=[1, 2, 3],
                                      sampling=SamplingParams(
                                          max_new_tokens=3)))
            comp = eng.result(good, timeout=120)
            assert len(comp.tokens) == 3
            with pytest.raises(AsyncEngineError, match="failed"):
                eng.result(bad, timeout=10)

    @pytest.mark.slow
    def test_shutdown_joins_thread_and_cancels_leftovers(self, tiny):
        _, model, params = tiny
        eng = AsyncEngine(model, params, max_len=48, max_running=2,
                          page_size=4, prefill_chunk=1,
                          prefix_cache=False)
        h = eng.submit(Request(uid=0, prompt=list(range(1, 40)),
                               sampling=SamplingParams(
                                   max_new_tokens=40)))
        eng.shutdown()
        assert not eng._thread.is_alive()
        assert h.state in (RequestState.CANCELLED, RequestState.FINISHED)
        assert eng.core.pool.n_live() == 0
        with pytest.raises(RuntimeError, match="shut down"):
            eng.submit(Request(uid=1, prompt=[1]))
        eng.shutdown()                               # idempotent

    @pytest.mark.slow
    def test_on_token_callback_streams_every_token_in_order(self, tiny):
        """submit(on_token=) is the push transport seam: the stepper
        must call it once per sampled token, in order, and the stream
        must equal the completion's tokens."""
        _, model, params = tiny
        pushed = {0: [], 1: []}
        with AsyncEngine(model, params, max_len=32, max_running=2,
                         page_size=4) as eng:
            handles = [
                eng.submit(Request(uid=0, prompt=p,
                                   sampling=SamplingParams(
                                       max_new_tokens=6)),
                           on_token=(lambda t, i=i: pushed[i].append(t)))
                for i, p in enumerate([[1, 2, 3], [7, 8]])]
            comps = [eng.result(h, timeout=300) for h in handles]
        for i, c in enumerate(comps):
            assert pushed[i] == c.tokens

    @pytest.mark.slow
    def test_raising_on_token_fails_only_that_handle(self, tiny):
        _, model, params = tiny
        with AsyncEngine(model, params, max_len=32, max_running=2,
                         page_size=4) as eng:
            bad = eng.submit(
                Request(uid=0, prompt=[1, 2, 3],
                        sampling=SamplingParams(max_new_tokens=6)),
                on_token=lambda t: 1 / 0)
            good = eng.submit(
                Request(uid=0, prompt=[4, 5],
                        sampling=SamplingParams(max_new_tokens=4)))
            # raising only on the FINAL token must still fail the
            # handle: callbacks run before the completion publishes
            seen = []

            def last_tok_boom(t):
                seen.append(t)
                if len(seen) == 3:
                    raise RuntimeError("final-token transport died")

            late = eng.submit(
                Request(uid=0, prompt=[9, 9],
                        sampling=SamplingParams(max_new_tokens=3)),
                on_token=last_tok_boom)
            comp = eng.result(good, timeout=300)   # engine survives
            assert len(comp.tokens) == 4
            with pytest.raises(AsyncEngineError) as ei:
                eng.result(bad, timeout=300)
            assert isinstance(ei.value.__cause__, ZeroDivisionError)
            with pytest.raises(AsyncEngineError) as ei2:
                eng.result(late, timeout=300)
            assert isinstance(ei2.value.__cause__, RuntimeError)
            assert len(seen) == 3
            # the failed handles' pages drained back to the pool
            assert eng.core.pool.n_live() == 0

    def test_emitted_feed_matches_generated(self, tiny):
        """StepResult.emitted is the async delivery feed: across a full
        core-driven run it must equal each sequence's generated list,
        in order."""
        _, model, params = tiny
        core = EngineCore(model, params, max_len=32, max_running=2,
                          page_size=4, clock=VirtualClock())
        seqs = [core.submit(Request(uid=i, prompt=[1 + i, 2, 3],
                                    sampling=SamplingParams(
                                        max_new_tokens=4)))
                for i in range(2)]
        per_uid = {0: [], 1: []}
        while core.has_work():
            for uid, tok in core.step().emitted:
                per_uid[uid].append(tok)
        for s in seqs:
            assert per_uid[s.uid] == s.generated


class TestStreamInteractive:
    """``launch/serve.py --interactive`` glue: a handle landing FAILED
    used to crash the session via the bare ``AsyncEngineError`` and
    drop the chained cause entirely; ``stream_interactive`` must print
    the cause and report a verdict instead."""

    def test_failed_handle_prints_chained_cause(self):
        from repro.launch.serve import stream_interactive
        from repro.serving.async_engine import AsyncEngineError

        class FakeEng:
            def stream(self, handle, timeout=None):
                yield 5
                err = AsyncEngineError("request 0 failed")
                err.__cause__ = ValueError("page budget exceeded")
                raise err

        class H:
            state = RequestState.FAILED

        out = []
        verdict = stream_interactive(FakeEng(), H(), out.append)
        assert verdict == "failed"
        text = "".join(out)
        assert "5" in text                      # tokens before the fall
        assert "request 0 failed" in text
        assert "ValueError" in text and "page budget exceeded" in text

    def test_timeout_cancels_and_reports_failed(self):
        from repro.launch.serve import stream_interactive

        class FakeEng:
            cancelled = []

            def stream(self, handle, timeout=None):
                raise TimeoutError("no token within 1 s")
                yield  # pragma: no cover

            def cancel(self, handle):
                self.cancelled.append(handle)
                return True

        class H:
            state = RequestState.DECODING

        eng, h, out = FakeEng(), H(), []
        assert stream_interactive(eng, h, out.append) == "failed"
        assert eng.cancelled == [h]
        assert "timed out" in "".join(out)

    @pytest.mark.slow
    def test_real_failed_handle_reports_cause(self, tiny):
        from repro.launch.serve import stream_interactive
        _, model, params = tiny
        with AsyncEngine(model, params, max_len=16, max_running=2,
                         page_size=4) as eng:
            bad = eng.submit(Request(uid=0, prompt=[1] * 17))
            out = []
            verdict = stream_interactive(eng, bad, out.append,
                                         timeout=120)
        assert verdict == "failed"
        assert bad.state is RequestState.FAILED
        # the engine-side validation error made it to the terminal
        assert "caused by" in "".join(out)
        assert "ValueError" in "".join(out)

    @pytest.mark.slow
    def test_real_cancelled_handle_reports_cancelled(self, tiny):
        from repro.launch.serve import stream_interactive
        _, model, params = tiny
        with AsyncEngine(model, params, max_len=64, max_running=2,
                         page_size=4, prefill_chunk=1,
                         prefix_cache=False) as eng:
            h = eng.submit(Request(uid=0, prompt=list(range(1, 40)),
                                   sampling=SamplingParams(
                                       max_new_tokens=50)))
            eng.cancel(h)
            out = []
            verdict = stream_interactive(eng, h, out.append, timeout=120)
        assert verdict == "cancelled"
        assert "cancelled" in "".join(out)
