"""Pytest config.

Markers are registered in ``pyproject.toml`` (``slow`` gates the CI
tier1 lane, which runs ``-m "not slow"``; the smoke lane runs the full
suite).

NOTE: no XLA_FLAGS device-count forcing here — in-process tests must
see the single real CPU device.  Multi-device behaviour is covered by
subprocess tests (tests/test_tp_distributed.py).
"""
