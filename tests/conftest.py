"""Pytest config.

NOTE: no XLA_FLAGS device-count forcing here — in-process tests must
see the single real CPU device.  Multi-device behaviour is covered by
subprocess tests (tests/test_tp_distributed.py).
"""

import pytest


def pytest_configure(config):
    config.addinivalue_line(
        "markers", "slow: subprocess / multi-device tests (minutes)")
