"""Tensor-parallel paged serving (PR: TP mesh over the paged engine).

Host level (fast lane): shard-striped KV page planning — per-(node,
shard) pools, byte accounting, placement — and the sliding-window
``release_below`` recycling groundwork (property-tested).

Device level (subprocess, ``slow``): everything needing a real
multi-device mesh runs in a child interpreter with forced host devices
(the in-process suite must keep the single real CPU device, see
``tests/conftest.py``):

* sharded-vs-single-shard greedy token parity, including shared-prefix,
  copy-on-write and chunked-prefill runs (the TP head merge is a
  zero-padded psum over disjoint head supports, so tokens must be
  byte-identical, not merely close);
* buffer donation still aliases each shard's per-layer pool buffers;
* ``core.tp.collective_ops_in`` on the compiled decode/prefill bodies:
  exactly one psum per layer, and no gather/scatter collective ever
  touches KV-page bytes.
"""

import os
import subprocess
import sys
import textwrap

import pytest
from _hypothesis_compat import given, settings, st

from repro.core.memory import MemoryManager
from repro.serving import KVCachePool, KVPoolConfig


def _run(snippet: str, devices: int = 2) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = (
        env.get("XLA_FLAGS", "")
        + f" --xla_force_host_platform_device_count={devices}")
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..",
                                     "src")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(snippet)],
                         capture_output=True, text=True, env=env,
                         timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


# ----------------------------------------------------------------------
# host-side planning (fast lane)
# ----------------------------------------------------------------------

class TestShardStripedPlanning:
    def test_per_node_per_shard_pools_split_page_bytes(self):
        mm = MemoryManager(2, numa=True)
        mm.plan_kv_pages(8, page_bytes=1024, n_shards=4)
        assert len(mm.kv_pools) == 2 * 4
        assert mm.kv_node_count == 2
        assert mm.kv_shard_count == 4
        # every shard of a node reserves the same bytes: its head slice
        # of each of the node's pages (4 pages x 1024/4, 128-aligned)
        peaks = {p.name: p.peak for p in mm.kv_pools}
        assert len(set(peaks.values())) == 1
        assert all(p.peak == 4 * 256 for p in mm.kv_pools)

    def test_node_striping_is_shard_invariant(self):
        flat = MemoryManager(4, numa=True)
        flat.plan_kv_pages(16, page_bytes=512)
        tp = MemoryManager(4, numa=True)
        tp.plan_kv_pages(16, page_bytes=512, n_shards=2)
        for pid in range(16):
            assert flat.kv_page_node(pid) == tp.kv_page_node(pid)
        node, shards = tp.kv_page_placement(5)
        assert node == tp.kv_page_node(5)
        assert shards == (0, 1)         # bytes live on every shard
        assert flat.kv_page_placement(5) == (flat.kv_page_node(5), (0,))

    def test_page_bytes_must_split_over_shards(self):
        mm = MemoryManager(1, numa=False)
        with pytest.raises(ValueError, match="split"):
            mm.plan_kv_pages(4, page_bytes=1000, n_shards=3)

    def test_pool_config_validates_kv_head_divisibility(self):
        with pytest.raises(ValueError, match="head-shard"):
            KVCachePool(KVPoolConfig(
                n_pages=9, page_size=4, n_layers=2, n_kv_heads=2,
                head_dim=8, n_shards=4))

    def test_pool_shard_accounting_and_node_hints(self):
        pool = KVCachePool(KVPoolConfig(
            n_pages=9, page_size=4, n_layers=2, n_kv_heads=4,
            head_dim=8, dtype_bytes=4, n_nodes=2, n_shards=2))
        assert pool.cfg.page_shard_bytes * 2 == pool.cfg.page_bytes
        per_shard = pool.capacity_bytes_per_shard()
        assert set(per_shard) == {0, 1}
        assert per_shard[0] == per_shard[1]
        per_node = pool.capacity_bytes_per_node()
        assert sum(per_node.values()) == sum(per_shard.values())
        # free lists stripe by NODE (a page's head-slices follow its
        # node), so both node pools hand out pages
        assert pool.grow(0, 16, node_hint=0)
        assert pool.grow(1, 16, node_hint=1)
        nodes = {pool.mm.kv_page_node(p)
                 for uid in (0, 1) for p in pool.block_table(uid)}
        assert nodes == {0, 1}


# ----------------------------------------------------------------------
# sliding-window page recycling groundwork
# ----------------------------------------------------------------------

def _pool(n_pages=17, page_size=4, prefix_cache=True, retain=True):
    return KVCachePool(KVPoolConfig(
        n_pages=n_pages, page_size=page_size, n_layers=2, n_kv_heads=2,
        head_dim=8, dtype_bytes=4),
        prefix_cache=prefix_cache, retain=retain)


class TestReleaseBelow:
    @given(n_tokens=st.integers(4, 60), pos=st.integers(0, 64))
    @settings(max_examples=40)
    def test_recycles_exactly_the_fully_below_pages(self, n_tokens, pos):
        pool = _pool(prefix_cache=False)
        assert pool.grow(0, n_tokens)
        table = pool.block_table(0)
        free0 = pool.n_free()
        dropped = pool.release_below(0, pos)
        expect = min(pos // 4, len(table))
        assert dropped == expect
        after = pool.block_table(0)
        assert len(after) == len(table)           # logical length kept
        assert after[:expect] == [0] * expect     # recycled -> scratch
        assert after[expect:] == table[expect:]   # tail untouched
        assert pool.n_free() == free0 + expect
        # recycled pages really are reusable
        for pid in table[:expect]:
            assert pool.refcount(pid) == 0
        # idempotent: nothing left below pos
        assert pool.release_below(0, pos) == 0
        pool.release(0)
        assert pool.n_live() == 0
        assert pool.n_free() == pool.cfg.n_pages - 1

    def test_partial_page_is_kept(self):
        pool = _pool(prefix_cache=False)
        pool.grow(0, 12)                          # 3 pages @ ps=4
        table = pool.block_table(0)
        # pos 7: page 0 fully below, page 1 still holds slot 7
        assert pool.release_below(0, 7) == 1
        assert pool.block_table(0) == [0] + table[1:]

    def test_shared_page_only_loses_one_reference(self):
        pool = _pool(prefix_cache=False)
        pool.grow(0, 8)
        shared = pool.block_table(0)
        pool.share_pages(1, shared)
        free0 = pool.n_free()
        assert pool.release_below(0, 8) == 2
        # uid 1 still owns the pages: nothing freed
        assert pool.n_free() == free0
        assert all(pool.refcount(p) == 1 for p in shared)
        pool.release(1)
        # uid0's table holds only recycled zeros now: pool fully free
        assert pool.n_free() == pool.cfg.n_pages - 1
        pool.release(0)
        assert pool.n_live() == 0

    def test_prefix_indexed_pages_retire_to_retention_lru(self):
        pool = _pool()
        tokens = list(range(1, 13))               # 3 full pages
        pool.grow(0, len(tokens) + 1)
        pool.register_prefix(0, tokens)
        table = pool.block_table(0)
        retained0 = pool.n_retained()
        assert pool.release_below(0, 8) == 2
        # both fully-below pages were prefix-indexed: cached-free LRU,
        # not the free list — a repeat prompt can still hit them
        assert pool.n_retained() == retained0 + 2
        match = pool.match_prefix(tokens + [99])
        assert match.pages == tuple(table[:3])
        pool.release(0)

    def test_growth_after_recycling_extends_the_tail(self):
        pool = _pool(n_pages=8, page_size=4, prefix_cache=False)
        pool.grow(0, 16)                          # 4 of 7 usable pages
        assert pool.release_below(0, 8) == 2
        assert pool.can_grow(0, 24)
        assert pool.grow(0, 24)                   # reuses recycled pages
        table = pool.block_table(0)
        assert len(table) == 6
        assert table[0] == table[1] == 0
        assert all(p != 0 for p in table[2:])


# ----------------------------------------------------------------------
# device level (subprocess, forced host devices)
# ----------------------------------------------------------------------

_CHILD_SETUP = """
    import numpy as np, jax
    import jax.numpy as jnp
    from repro.models import ModelConfig, build_model
    from repro.serving import (ContinuousServingEngine, Request,
                               SamplingParams)
    from repro.launch.mesh import make_mesh

    cfg = ModelConfig(name="tp-tiny", arch_type="dense", n_layers=3,
                      d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
                      vocab_size=259, dtype=jnp.float32)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
"""


@pytest.mark.slow
def test_tp_greedy_parity_incl_prefix_cow_chunked():
    """Byte-identical tokens at shards {1, 2} vs the plain engine on a
    two-wave workload that exercises prefix sharing, mid-page CoW and
    chunked prefill (stats assert the TP run really shared/cloned)."""
    print(_run(_CHILD_SETUP + """
    rng = np.random.default_rng(11)
    system = list(rng.integers(1, 258, 20))   # 2 full pages + 4 tokens
    # 26-token prompts: block 3 (tokens 16..23) fills completely and
    # registers, so wave 2's divergence at token 20 is a mid-page CoW
    wave1 = [Request(uid=i, prompt=system + list(rng.integers(1, 258, 6)),
                     sampling=SamplingParams(max_new_tokens=8))
             for i in range(2)]
    wave2 = [Request(uid=9 + i,
                     prompt=system + list(rng.integers(1, 258, 6)),
                     sampling=SamplingParams(max_new_tokens=8))
             for i in range(2)]

    def run(mesh=None, n_nodes=1):
        eng = ContinuousServingEngine(
            model, params, max_len=64, max_running=4, page_size=8,
            prefill_chunk=8, mesh=mesh, n_nodes=n_nodes)
        toks = [c.tokens for c in eng.generate(wave1)]
        toks += [c.tokens for c in eng.generate(wave2)]
        return eng, toks

    _, ref = run()
    for shards in (1, 2):
        mesh = make_mesh((shards,), ("model",))
        eng, got = run(mesh, n_nodes=shards)
        assert got == ref, (shards, got, ref)
        st = eng.pool.stats
        assert st["shared_pages"] > 0, st      # prefix pages shared
        assert st["cow_copies"] > 0, st        # mid-page divergence
        assert st["retention_hits"] > 0, st    # cross-wave reuse
    print("TP-PARITY-OK")
    """))


@pytest.mark.slow
def test_tp_donation_aliases_per_shard_buffers():
    print(_run(_CHILD_SETUP + """
    mesh = make_mesh((2,), ("model",))
    eng = ContinuousServingEngine(model, params, max_len=64,
                                  max_running=4, page_size=8, mesh=mesh)
    eng.generate([Request(uid=0, prompt=[1, 2, 3],
                          sampling=SamplingParams(max_new_tokens=2))])
    r = eng.core.runner
    assert r.tp_shards == 2
    k = r.cache["layers"][0]["self"]["k"]
    assert [s.data.shape[1] for s in k.addressable_shards] == [1, 1]
    ptrs0 = sorted(s.data.unsafe_buffer_pointer()
                   for s in k.addressable_shards)
    logits = r.decode(np.zeros((4, 1), np.int32),
                      np.full((4,), -1, np.int32))
    jax.block_until_ready(logits)
    k1 = r.cache["layers"][0]["self"]["k"]
    ptrs1 = sorted(s.data.unsafe_buffer_pointer()
                   for s in k1.addressable_shards)
    assert ptrs0 == ptrs1, (ptrs0, ptrs1)   # donated: scatter in place
    print("TP-DONATION-OK")
    """))


@pytest.mark.slow
def test_tp_collectives_one_psum_per_layer_no_kv_gather():
    """The §3.4 Sync-B budget: decode and prefill bodies contain exactly
    n_layers psums (the per-layer head merge) and not a single
    gather/scatter collective — KV-page bytes never cross shards."""
    print(_run(_CHILD_SETUP + """
    from repro.core.tp import collective_ops_in
    mesh = make_mesh((2,), ("model",))
    eng = ContinuousServingEngine(model, params, max_len=64,
                                  max_running=4, page_size=8, mesh=mesh)
    r = eng.core.runner
    toks = jnp.ones((4, 1), jnp.int32)
    pos = jnp.zeros((4,), jnp.int32)
    counts = collective_ops_in(r.tp_raw_decode, r.params, r.cache,
                               toks, pos)
    assert counts.get("psum") == cfg.n_layers, counts
    assert set(counts) == {"psum"}, counts

    # prefill (fresh + resumed-chunk buckets): same budget — the jitted
    # wrapper's jaxpr nests the shard_map body, which the walker visits
    batch = {"tokens": jnp.ones((1, 8), jnp.int32)}
    sl = jnp.asarray(0, jnp.int32)
    pl = jnp.asarray(8, jnp.int32)
    c_fresh = collective_ops_in(r._prefill_fn(8, 0), r.params, batch,
                                r.cache, sl, pl)
    c_chunk = collective_ops_in(r._prefill_fn(8, 4), r.params, batch,
                                r.cache, sl, pl, jnp.asarray(8, jnp.int32))
    for counts in (c_fresh, c_chunk):
        assert counts.get("psum") == cfg.n_layers, counts
        assert set(counts) == {"psum"}, counts
    print("TP-COLLECTIVES-OK")
    """))


@pytest.mark.slow
def test_tp_rejects_indivisible_heads_and_bad_policy():
    print(_run(_CHILD_SETUP + """
    from repro.launch.shardings import Policy
    mesh = make_mesh((2,), ("model",))
    bad = ModelConfig(name="odd", arch_type="dense", n_layers=2,
                      d_model=63, n_heads=3, n_kv_heads=1, d_ff=64,
                      vocab_size=259, dtype=jnp.float32)
    bad_model = build_model(bad)
    bad_params = bad_model.init(jax.random.PRNGKey(0))
    try:
        ContinuousServingEngine(bad_model, bad_params, max_len=32,
                                max_running=2, page_size=8, mesh=mesh)
        raise SystemExit("expected ValueError for indivisible heads")
    except ValueError as e:
        assert "head" in str(e)
    try:
        ContinuousServingEngine(
            model, params, max_len=32, max_running=2, page_size=8,
            mesh=mesh, policy=Policy(shard_cache_head_dim=False))
        raise SystemExit("expected ValueError for bad policy")
    except ValueError as e:
        assert "head-sharded" in str(e)
    print("TP-VALIDATE-OK")
    """))
