"""Paged KV pool, continuous-batching scheduler + engine (PR: serving).

Property tests: page aliasing, free-list reuse, NUMA byte accounting.
System tests: greedy token parity with the bucket engine (including
under forced preemption), late-arrival admission without recompiling
the decode step, paged Pallas kernel vs jnp oracle.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.memory import MemoryManager, _align
from repro.models import ModelConfig, build_model
from repro.serving import (ContinuousServingEngine, ContinuousScheduler,
                           KVCachePool, KVPoolConfig, Request,
                           SamplingParams, ServingEngine)


def _pool(n_pages=17, page_size=4, n_nodes=1, numa=True):
    return KVCachePool(KVPoolConfig(
        n_pages=n_pages, page_size=page_size, n_layers=2, n_kv_heads=2,
        head_dim=8, dtype_bytes=4, n_nodes=n_nodes, numa=numa))


class TestKVPool:
    def test_scratch_page_never_allocated(self):
        pool = _pool(n_pages=5)
        for uid in range(4):
            assert pool.grow(uid, 4)
        assert pool.n_free() == 0
        for uid in range(4):
            assert 0 not in pool.block_table(uid)

    @given(ops=st.lists(st.integers(0, 9), min_size=1, max_size=60))
    @settings(max_examples=25, deadline=None)
    def test_pages_never_alias_across_live_sequences(self, ops):
        """Random grow/free interleavings: a physical page is owned by
        at most one live sequence, and ownership matches block tables."""
        pool = _pool(n_pages=13, n_nodes=2)
        lens = {}
        for i, op in enumerate(ops):
            uid = op % 3
            if op < 6:   # grow by 1..6 tokens
                want = lens.get(uid, 0) + 1 + op
                if pool.cfg.pages_for(want) <= pool.cfg.max_pages_per_seq:
                    if pool.grow(uid, want):
                        lens[uid] = want
            else:        # free
                pool.free(uid)
                lens.pop(uid, None)
            tables = {u: pool.block_table(u) for u in lens}
            seen = {}
            for u, pages in tables.items():
                assert len(pages) == pool.cfg.pages_for(lens[u])
                for p in pages:
                    assert p != 0, "scratch page leaked"
                    assert p not in seen, f"page {p} aliased {seen.get(p)}/{u}"
                    seen[p] = u
            assert len(seen) + pool.n_free() == pool.cfg.n_pages - 1

    def test_freed_pages_are_reused(self):
        pool = _pool(n_pages=9)
        assert pool.grow(0, 32)          # all 8 usable pages
        first = pool.block_table(0)
        pool.free(0)
        assert pool.grow(1, 32)
        assert sorted(pool.block_table(1)) == sorted(first)
        # LIFO: the most recently freed (cache-warm) page comes first
        assert pool.block_table(1)[0] == first[-1]

    def test_per_node_accounting_matches_memory_manager(self):
        cfg = KVPoolConfig(n_pages=12, page_size=4, n_layers=3,
                           n_kv_heads=2, head_dim=8, dtype_bytes=4,
                           n_nodes=4, numa=True)
        pool = KVCachePool(cfg)
        cap = pool.capacity_bytes_per_node()
        # planner view: per-node totals of the shared MemoryManager
        assert {n: b for n, b in pool.mm.per_node_bytes().items() if b} \
            == {n: b for n, b in cap.items() if b}
        # 12 pages round-robin over 4 nodes = 3 aligned carve-outs each
        assert all(b == 3 * _align(cfg.page_bytes) for b in cap.values())
        # home-node allocation: node 0's usable pages (2 — one of its 3
        # carve-outs is the scratch page) go first, then spill to the
        # fullest other free-lists
        pool.grow(0, 16, node_hint=0)    # 4 pages
        live = pool.live_bytes_per_node()
        assert sum(live.values()) == 4 * cfg.page_bytes
        assert live[0] == 2 * cfg.page_bytes, "home node filled first"
        assert all(live[n] <= cap[n] for n in live)

    def test_kv_pages_sit_alongside_weights_in_one_plan(self):
        """KV pages extend the same planner as weights/activations."""
        from repro.core.tensor import OpType, make_header
        mm = MemoryManager(2, numa=True)
        for i in range(4):
            mm.place_weight(make_header((64,), np.float32, op=OpType.WEIGHT,
                                        name=f"w{i}", node_id=i % 2))
        cfg = KVPoolConfig(n_pages=4, page_size=4, n_layers=2, n_kv_heads=2,
                           head_dim=8, n_nodes=2, numa=True)
        KVCachePool(cfg, mm=mm)
        per_node = mm.per_node_bytes()
        want_w = 2 * _align(64 * 4)
        want_kv = 2 * _align(cfg.page_bytes)
        assert per_node == {0: want_w + want_kv, 1: want_w + want_kv}
        assert mm.total_bytes() == 2 * (want_w + want_kv)


class TestScheduler:
    def _sched(self, **kw):
        pool = _pool(**{k: v for k, v in kw.items()
                        if k in ("n_pages", "page_size")})
        return ContinuousScheduler(pool, max_running=kw.get("max_running", 2),
                                   max_len=kw.get("max_len", 64))

    def test_fcfs_admission_into_free_slots(self):
        s = self._sched(max_running=2)
        for i in range(3):
            s.submit(Request(uid=i, prompt=[1, 2, 3]), arrival=float(i))
        plan = s.step(now=10.0)
        assert [q.uid for q in plan.prefills] == [0, 1]
        assert len(s.waiting) == 1 and s.waiting[0].uid == 2

    def test_arrival_time_gates_admission(self):
        s = self._sched(max_running=2)
        s.submit(Request(uid=0, prompt=[1]), arrival=5.0)
        assert s.step(now=0.0).prefills == []
        assert [q.uid for q in s.step(now=6.0).prefills] == [0]

    def test_eviction_frees_slot_and_pages(self):
        s = self._sched(max_running=1)
        s.submit(Request(uid=0, prompt=[1, 2],
                         sampling=SamplingParams(max_new_tokens=1)))
        s.submit(Request(uid=1, prompt=[3, 4]))
        plan = s.step()
        assert [q.uid for q in plan.prefills] == [0]
        seq = plan.prefills[0]
        seq.n_prefilled = seq.prefill_target   # engine ran the prefill
        seq.generated.append(42)          # hits max_new_tokens
        plan = s.step()
        assert [q.uid for q in plan.finished] == [0]
        assert [q.uid for q in plan.prefills] == [1]
        assert s.pool.block_table(0) == []

    def test_preemption_evicts_youngest_and_requeues(self):
        # 6 usable pages, page_size 4: two decoding sequences that both
        # cross a page boundary cannot both fit
        s = self._sched(max_running=2, n_pages=7, page_size=4)
        a = s.submit(Request(uid=0, prompt=[1] * 8), arrival=0.0)   # 3 pages
        b = s.submit(Request(uid=1, prompt=[1] * 8), arrival=1.0)
        plan = s.step(now=2.0)
        assert {q.uid for q in plan.prefills} == {0, 1}
        for seq in (a, b):
            seq.n_prefilled = seq.prefill_target   # engine ran the prefill
            seq.generated.extend([7] * 4)     # decode to a page boundary
        plan = s.step(now=3.0)
        assert [q.uid for q in plan.preempted] == [1], "youngest loses"
        assert b.slot == -1 and s.pool.block_table(1) == []
        assert s.waiting[0].uid == 1
        assert b.full_prompt == [1] * 8 + [7] * 4  # recompute-style requeue
        assert [q.uid for q in plan.decodes] == [0]


@pytest.fixture(scope="module")
def tiny():
    cfg = ModelConfig(name="tiny", arch_type="dense", n_layers=2,
                      d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
                      vocab_size=259, dtype=jnp.float32)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


MIXED_PROMPTS = [[1, 2, 3, 4, 5], [7, 8, 9], [10, 11, 12, 13, 14, 15, 16],
                 [5, 4, 3], [9, 9, 2, 1]]


class TestContinuousEngine:
    @pytest.mark.slow
    def test_greedy_token_parity_with_bucket_engine(self, tiny):
        _, model, params = tiny
        reqs = [Request(uid=i, prompt=p,
                        sampling=SamplingParams(max_new_tokens=5))
                for i, p in enumerate(MIXED_PROMPTS)]
        bc = ServingEngine(model, params, max_len=48).generate(
            reqs, max_batch=4)
        cc = ContinuousServingEngine(
            model, params, max_len=48, max_running=3,
            page_size=4).generate(reqs)
        assert [c.tokens for c in bc] == [c.tokens for c in cc]

    @pytest.mark.slow
    def test_preemption_preserves_greedy_tokens(self, tiny):
        """Starved pool: preempted sequences recompute and still match."""
        _, model, params = tiny
        reqs = [Request(uid=i, prompt=p,
                        sampling=SamplingParams(max_new_tokens=6))
                for i, p in enumerate(MIXED_PROMPTS)]
        bc = ServingEngine(model, params, max_len=48).generate(
            reqs, max_batch=4)
        eng = ContinuousServingEngine(model, params, max_len=48,
                                      max_running=3, page_size=4, n_pages=8)
        cc = eng.generate(reqs)
        assert eng.scheduler.n_preemptions > 0, "pool was not starved"
        assert [c.tokens for c in bc] == [c.tokens for c in cc]

    def test_late_arrival_admits_without_recompile(self, tiny):
        _, model, params = tiny
        reqs = [Request(uid=i, prompt=[3 + i, 5, 7],
                        sampling=SamplingParams(max_new_tokens=8))
                for i in range(4)]
        eng = ContinuousServingEngine(model, params, max_len=32,
                                      max_running=4, page_size=4)
        # request 3 arrives mid-decode of 0..2
        comps = eng.generate(reqs, arrivals=[0.0, 0.0, 0.0, 0.3])
        assert all(len(c.tokens) == 8 for c in comps)
        # one decode compilation serves every batch membership
        assert eng._decode._cache_size() == 1

    def test_prefill_pad_overrun_stays_on_scratch_page(self, tiny):
        """A prompt whose padded prefill bucket exceeds the block-table
        span (41 -> padded 64 > 6 pages * 8 slots) must not let padding
        rows clamp into the sequence's last real page."""
        _, model, params = tiny
        rng = np.random.default_rng(3)
        reqs = [Request(uid=0, prompt=list(rng.integers(1, 258, 41)),
                        sampling=SamplingParams(max_new_tokens=6))]
        bc = ServingEngine(model, params, max_len=48).generate(reqs)
        cc = ContinuousServingEngine(model, params, max_len=48,
                                     max_running=2,
                                     page_size=8).generate(reqs)
        assert bc[0].tokens == cc[0].tokens

    def test_oversized_prompt_rejected_cleanly(self, tiny):
        _, model, params = tiny
        eng = ContinuousServingEngine(model, params, max_len=32,
                                      page_size=8)
        with pytest.raises(ValueError, match="does not fit max_len"):
            eng.generate([Request(uid=0, prompt=[1] * 33)])

    def test_idle_slots_are_inert(self, tiny):
        """A lone request in a wide batch decodes as if alone."""
        _, model, params = tiny
        req = [Request(uid=0, prompt=[1, 2, 3, 4, 5],
                       sampling=SamplingParams(max_new_tokens=5))]
        wide = ContinuousServingEngine(model, params, max_len=32,
                                       max_running=8, page_size=4)
        narrow = ContinuousServingEngine(model, params, max_len=32,
                                         max_running=1, page_size=4)
        assert (wide.generate(req)[0].tokens
                == narrow.generate(req)[0].tokens)


class TestPagedKernel:
    @given(b=st.integers(1, 3), mp=st.integers(1, 4),
           g=st.sampled_from([1, 2]))
    @settings(max_examples=8, deadline=None)
    @pytest.mark.slow
    def test_pallas_kernel_matches_ref(self, b, mp, g):
        from repro.kernels.decode_attention import paged_decode_attention
        from repro.kernels.ref import paged_decode_attention_ref
        rng = np.random.default_rng(b * 100 + mp * 10 + g)
        Hkv, D, ps, P = 2, 8, 4, 9
        q = rng.normal(size=(b, Hkv, g, D)).astype(np.float32)
        kp = rng.normal(size=(P, ps, Hkv, D)).astype(np.float32)
        vp = rng.normal(size=(P, ps, Hkv, D)).astype(np.float32)
        bt = rng.integers(1, P, size=(b, mp)).astype(np.int32)
        lens = rng.integers(0, mp * ps + 1, size=(b,)).astype(np.int32)
        for window in (0, 3):
            ref = paged_decode_attention_ref(jnp.asarray(q), kp, vp, bt,
                                             lens, window)
            ker = paged_decode_attention(jnp.asarray(q), jnp.asarray(kp),
                                         jnp.asarray(vp), bt, lens, window,
                                         interpret=True)
            np.testing.assert_allclose(np.asarray(ref), np.asarray(ker),
                                       rtol=1e-5, atol=1e-5)


class TestScanEscapeLayout:
    """Per-layer paged-pool buffers outside the layer-scan carry.

    The compiled decode/prefill step must (a) hold each layer's K/V
    pool as an independent buffer XLA can donate, (b) update those
    buffers in place (output aliases input — no O(pool bytes) copy per
    step), and (c) produce results that do not depend on how large the
    pool is, only on the pages the block table maps.
    """

    def _paged_cache(self, model, n_pages, *, B=2, max_len=32, ps=4,
                     ctx=8, seed_rows=False):
        cache = model.init_cache(B, max_len, page_size=ps,
                                 n_pages=n_pages)
        pps = ctx // ps + 1                  # resident ctx + decode page
        bt = np.zeros((B, max_len // ps), np.int32)
        for b in range(B):
            bt[b, :pps] = 1 + b * pps + np.arange(pps)
        cache["block_tables"] = jnp.asarray(bt)
        if seed_rows:
            # deterministic resident K/V in the mapped rows only: the
            # same physical rows exist in every pool size, so results
            # must match exactly across the sweep
            rows = np.concatenate([
                bt[b, :ctx // ps].repeat(ps) * ps
                + np.tile(np.arange(ps), ctx // ps)
                for b in range(B)])
            for i, lyr in enumerate(cache["layers"]):
                H, D = lyr["self"]["k"].shape[1:]
                vals = (np.arange(len(rows) * H * D, dtype=np.float32)
                        .reshape(len(rows), H, D) % 7 - 3) * 0.1 * (i + 1)
                lyr["self"]["k"] = lyr["self"]["k"].at[rows].set(vals)
                lyr["self"]["v"] = lyr["self"]["v"].at[rows].set(-vals)
        return cache

    def test_cache_layers_are_independent_buffers(self, tiny):
        cfg, model, _ = tiny
        cache = model.init_cache(2, 32, page_size=4, n_pages=9)
        layers = cache["layers"]
        assert isinstance(layers, list) and len(layers) == cfg.n_layers
        shape = (9 * 4, cfg.n_kv_heads, 64 // 4)
        for lyr in layers:
            assert lyr["self"]["k"].shape == shape
            assert lyr["self"]["v"].shape == shape

    def test_decode_step_aliases_donated_buffers_in_place(self, tiny):
        """With donation, every layer buffer's output must reuse the
        input's device memory — the step costs O(touched bytes)."""
        _, model, params = tiny
        ps, B = 4, 2
        decode = jax.jit(
            lambda p, c, t, pos: model.decode_step(p, c, t, pos,
                                                   page_size=ps),
            donate_argnums=1)
        cache = self._paged_cache(model, n_pages=11, ps=ps, B=B)
        toks = jnp.ones((B, 1), jnp.int32)
        pos = jnp.full((B,), 8, jnp.int32)
        _, cache = decode(params, cache, toks, pos)      # compile+warm
        ptr_in = [lyr["self"][kv].unsafe_buffer_pointer()
                  for lyr in cache["layers"] for kv in ("k", "v")]
        _, cache = decode(params, cache, toks, pos)
        ptr_out = [lyr["self"][kv].unsafe_buffer_pointer()
                   for lyr in cache["layers"] for kv in ("k", "v")]
        assert ptr_in == ptr_out

    def test_decode_pool_size_invariance(self, tiny):
        """8x pool sweep at identical touched pages: logits and the
        touched cache rows must be bit-identical."""
        _, model, params = tiny
        ps, B, ctx = 4, 2, 8
        decode = jax.jit(
            lambda p, c, t, pos: model.decode_step(p, c, t, pos,
                                                   page_size=ps))
        toks = jnp.asarray([[3], [7]], jnp.int32)
        pos = jnp.full((B,), ctx, jnp.int32)
        results = {}
        for P in (11, 88):
            cache = self._paged_cache(model, P, ps=ps, B=B, ctx=ctx,
                                      seed_rows=True)
            logits, nc = decode(params, cache, toks, pos)
            touched = [np.asarray(lyr["self"][kv][:11 * ps])
                       for lyr in nc["layers"] for kv in ("k", "v")]
            results[P] = (np.asarray(logits), touched)
        np.testing.assert_array_equal(results[11][0], results[88][0])
        for a, b in zip(results[11][1], results[88][1]):
            np.testing.assert_array_equal(a, b)

    def test_prefill_chunk_pool_size_invariance(self, tiny):
        """Resumed prefill chunk over the same resident context must
        also be pool-size independent."""
        _, model, params = tiny
        ps, B, ctx = 4, 2, 8
        prefill = jax.jit(
            lambda p, b, c, slot, plen, start: model.prefill_paged(
                p, b, c, slot, plen, start=start, ctx_pages=4,
                page_size=ps))
        chunk = {"tokens": jnp.asarray([[5, 6, 7, 8]], jnp.int32)}
        out = {}
        for P in (11, 88):
            cache = self._paged_cache(model, P, ps=ps, B=B, ctx=ctx,
                                      seed_rows=True)
            logits, _ = prefill(params, chunk, cache,
                                jnp.asarray(1, jnp.int32),
                                jnp.asarray(4, jnp.int32),
                                jnp.asarray(ctx, jnp.int32))
            out[P] = np.asarray(logits)
        np.testing.assert_array_equal(out[11], out[88])
