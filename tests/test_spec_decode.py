"""Self-speculative decoding (PR: prompt-lookup drafts + paged verify).

Three layers, mirroring the subsystem:

* **Drafter properties** (fast, no jax): every proposal is a
  contiguous slice of the sequence's own history, capped at ``k``;
  the lookahead clamp respects prefill state, sampling temperature
  and the remaining token budget.
* **Rollback invariants** (property tests, satellite of the PR):
  :meth:`~repro.serving.kv_pool.KVCachePool.truncate_to` under
  arbitrary accept/reject patterns — refcounts, free lists, retention
  LRU and ``pending_copies`` exact, including mid-page rejection on
  shared (copy-on-write) pages.
* **Byte parity** (the acceptance gate): greedy tokens with
  ``spec_decode=k`` byte-identical to ``k=0`` across plain,
  shared-prefix, mid-page-CoW, chunked-prefill and forced-preemption
  runs — speculation may only change *when* tokens are computed,
  never *which*.
"""

import types

import pytest
from _hypothesis_compat import given, settings, st

from repro.serving import (ContinuousServingEngine, KVCachePool,
                           KVPoolConfig, Request, SamplingParams,
                           VirtualClock)
from repro.serving.spec import MAX_NGRAM, lookahead_for, propose


# ----------------------------------------------------------------------
# drafter properties (no jax)
# ----------------------------------------------------------------------
def _seq(next_pos=10, n_generated=2, *, prefilling=False,
         temperature=0.0, max_new=64):
    """Minimal Sequence stand-in for ``lookahead_for``."""
    return types.SimpleNamespace(
        next_pos=next_pos, generated=[7] * n_generated,
        is_prefilling=prefilling,
        request=types.SimpleNamespace(sampling=SamplingParams(
            temperature=temperature, max_new_tokens=max_new)))


class TestPropose:
    def test_repeated_ngram_proposes_its_continuation(self):
        # suffix [1, 2, 3] recurs at the front; the draft replays what
        # followed it there
        assert propose([1, 2, 3, 4, 1, 2, 3], 2) == [4, 1]

    def test_most_recent_occurrence_wins(self):
        # [1, 2] appears twice; the later (more recent) continuation
        # is the better guess for the current regime
        ctx = [1, 2, 9, 5, 1, 2, 8, 5, 1, 2]
        assert propose(ctx, 1) == [8]

    def test_longer_ngrams_beat_shorter_ones(self):
        # a 3-gram match exists and must win over the 1-gram match
        # that points somewhere else
        ctx = [5, 1, 2, 3, 7, 7, 3, 1, 2, 3]
        assert propose(ctx, 1) == [7]

    def test_no_repetition_no_draft(self):
        assert propose(list(range(20)), 4) == []

    def test_degenerate_inputs(self):
        assert propose([], 4) == []
        assert propose([1], 4) == []
        assert propose([1, 2, 3], 0) == []
        assert propose([1, 2, 3], -1) == []

    @given(ctx=st.lists(st.integers(0, 3), max_size=40),
           k=st.integers(1, 6))
    @settings(max_examples=60, deadline=None)
    def test_draft_is_a_contiguous_slice_of_history(self, ctx, k):
        d = propose(ctx, k)
        assert len(d) <= k
        if d:
            # the draft was copied verbatim from somewhere in history
            assert any(ctx[i:i + len(d)] == d
                       for i in range(len(ctx) - len(d) + 1))
            # and the matched n-gram really is the current suffix
            for size in range(MAX_NGRAM, 0, -1):
                if len(ctx) > size and any(
                        ctx[i:i + size] == ctx[-size:]
                        for i in range(len(ctx) - size)):
                    break
            else:
                pytest.fail("draft without a repeated suffix n-gram")


class TestLookahead:
    def test_clamps_to_k(self):
        assert lookahead_for(_seq(), 4, max_len=100) == 4

    def test_zero_during_prefill(self):
        assert lookahead_for(_seq(prefilling=True), 4, max_len=100) == 0

    def test_zero_when_sampling(self):
        # byte parity is a greedy contract; sampled lanes never draft
        assert lookahead_for(_seq(temperature=0.7), 4, max_len=100) == 0

    def test_clamps_to_max_len(self):
        # next_pos 10: verify writes positions 10..10+k, all < max_len
        assert lookahead_for(_seq(next_pos=10), 8, max_len=13) == 2

    def test_clamps_to_token_budget(self):
        # 2 generated of max_new 4: at most 2 more tokens, one of which
        # the verify step's bonus token covers
        assert lookahead_for(_seq(n_generated=2, max_new=4), 8,
                             max_len=100) == 1

    def test_never_negative(self):
        assert lookahead_for(_seq(n_generated=63, max_new=64), 4,
                             max_len=100) == 0


# ----------------------------------------------------------------------
# rollback invariants (truncate_to property tests)
# ----------------------------------------------------------------------
def _pool(n_pages=17, page_size=4, **kw):
    return KVCachePool(KVPoolConfig(
        n_pages=n_pages, page_size=page_size, n_layers=2, n_kv_heads=2,
        head_dim=8, dtype_bytes=4), **kw)


USABLE = 16     # _pool default: n_pages - 1


class TestTruncateRollback:
    @given(rounds=st.lists(st.tuples(st.integers(0, 4),
                                     st.integers(0, 4)),
                           min_size=1, max_size=30))
    @settings(max_examples=40, deadline=None)
    def test_arbitrary_accept_reject_patterns(self, rounds):
        """Each round mimics one speculative step: grow for the
        worst-case k, the verify accepts a <= k, truncate to the real
        frontier.  The table must cover exactly the accepted tokens and
        page accounting must conserve after every round."""
        pool = _pool(n_pages=33, prefix_cache=False)
        n = 5
        assert pool.grow(0, n)
        for k, a in rounds:
            a = min(a, k)
            if not pool.grow(0, n + 1 + k):
                break                       # pool dry: scheduler's problem
            n += 1 + a                      # bonus token + accepted draft
            pool.truncate_to(0, n)
            table = pool.block_table(0)
            assert len(table) == pool.cfg.pages_for(n)
            assert all(pool.refcount(p) == 1 for p in table)
            assert 0 not in table
            # conservation: live + free + retained == usable pages
            assert pool.n_live() + pool.n_free() == 32

    def test_truncate_is_the_exact_inverse_of_overgrow(self):
        pool = _pool()
        assert pool.grow(0, 6)
        before = (pool.block_table(0), pool.n_free())
        assert pool.grow(0, 6 + 5)          # worst-case k=4 + bonus
        assert pool.truncate_to(0, 6) == 1  # the page-3 grant returns
        assert (pool.block_table(0), pool.n_free()) == before
        assert pool.truncate_to(0, 6) == 0  # all-accepted fast path

    def test_freed_pages_are_immediately_reusable(self):
        pool = _pool(n_pages=5)             # 4 usable pages
        assert pool.grow(0, 4)
        assert pool.grow(0, 16)             # speculative worst case
        assert pool.n_free() == 0
        pool.truncate_to(0, 4)
        assert pool.grow(1, 12)             # another sequence takes them

    def test_shared_prefix_pages_keep_their_other_owner(self):
        # A's registered prompt pages are shared into B; B's rollback
        # below the shared span drops *references*, never A's bytes
        pool = _pool()
        prompt = list(range(8))
        assert pool.grow(0, 8)
        pool.register_prefix(0, prompt)
        m = pool.match_prefix(prompt + [99])
        assert m.pages and pool.adopt_prefix(1, m)
        shared = pool.block_table(1)
        assert all(pool.refcount(p) == 2 for p in shared)
        assert pool.grow(1, 8 + 5)          # speculative span
        pool.truncate_to(1, 4)              # reject below the share
        assert pool.block_table(1) == [shared[0]]
        assert pool.refcount(shared[0]) == 2
        assert pool.block_table(0) == list(shared) + \
            [p for p in pool.block_table(0) if p not in shared]
        assert all(pool.refcount(p) >= 1 for p in pool.block_table(0))

    def test_midpage_rejection_on_cow_pages_drops_the_queued_copy(self):
        # B diverges from A's cached prompt mid-page: adoption queues a
        # (src, dst) device copy for the CoW clone.  A rollback that
        # drops the clone before the engine applied the copy must also
        # drop the queued copy — the page's next owner is not a clone
        # target.
        pool = _pool()
        a_prompt = list(range(8))           # two full pages
        assert pool.grow(0, 8)
        pool.register_prefix(0, a_prompt)
        b_prompt = a_prompt[:6] + [77, 78]  # diverges inside page 2
        m = pool.match_prefix(b_prompt)
        assert m.cow_src is not None and m.cow_len == 2
        assert pool.adopt_prefix(1, m)
        clone = pool.block_table(1)[-1]
        assert pool.pending_copies == [(m.cow_src, clone)]
        assert pool.truncate_to(1, 4) == 1  # mid-page rejection: clone dies
        assert pool.pending_copies == []
        assert pool.refcount(clone) == 0
        assert pool.refcount(m.cow_src) == 1    # A still owns the source

    def test_cow_write_guard_then_rollback_restores_sharing(self):
        # the scheduler CoWs the speculative span's pages before the
        # verify write; rejecting everything afterwards must return the
        # private clone and leave the original share intact
        pool = _pool()
        prompt = list(range(4))
        assert pool.grow(0, 4)
        pool.register_prefix(0, prompt)
        pool.share_pages(1, pool.block_table(0))
        shared = pool.block_table(1)[0]
        free0 = pool.n_free()
        assert pool.ensure_writable(1, 0)
        clone = pool.block_table(1)[0]
        assert clone != shared and pool.pending_copies
        pool.truncate_to(1, 0)
        assert pool.pending_copies == []
        assert pool.refcount(shared) == 1 and pool.n_free() == free0

    def test_prefix_indexed_pages_retire_to_retention_not_free(self):
        # a rolled-back page whose bytes index a cached prefix keeps
        # them resident (retention LRU), exactly like free()
        pool = _pool()
        prompt = list(range(8))
        assert pool.grow(0, 8)
        pool.register_prefix(0, prompt)
        free0 = pool.n_free()
        retained0 = pool.n_retained()
        assert pool.truncate_to(0, 4) == 1
        assert pool.n_retained() == retained0 + 1
        assert pool.n_free() == free0 + 1   # retained still allocatable
        # ... and a repeat prompt still hits the retained page
        m = pool.match_prefix(prompt + [5])
        assert m.n_tokens >= 4


# ----------------------------------------------------------------------
# byte parity (the acceptance gate)
# ----------------------------------------------------------------------
def _tiny():
    import jax
    import jax.numpy as jnp

    from repro.models import ModelConfig, build_model
    cfg = ModelConfig(name="spec-tiny", arch_type="dense", n_layers=2,
                      d_model=32, n_heads=2, n_kv_heads=2, d_ff=64,
                      vocab_size=67, dtype=jnp.float32)
    model = build_model(cfg)
    return model, model.init(jax.random.PRNGKey(0))


#: repetitive prompts so prompt-lookup actually drafts (and greedy
#: continuations of an untrained model still reject most of them —
#: both sides of accept/rollback run)
REP = [[7, 8, 9, 7, 8, 9, 7, 8], [3, 4, 3, 4, 3, 4, 3, 4, 3],
       [5, 6, 7, 5, 6, 7, 5, 6, 7, 5]]


def _generate(model, params, prompts, k, *, max_new=12, **kw):
    eng = ContinuousServingEngine(model, params, spec_decode=k,
                                  clock=VirtualClock(), **kw)
    reqs = [Request(uid=i, prompt=list(p),
                    sampling=SamplingParams(max_new_tokens=max_new))
            for i, p in enumerate(prompts)]
    comps = eng.generate(reqs)
    return [c.tokens for c in comps], eng


class TestGreedyByteParity:
    def _assert_parity(self, prompts, *, max_new=12, **kw):
        model, params = _tiny()
        base, _ = _generate(model, params, prompts, 0, max_new=max_new,
                            **kw)
        for k in (2, 4):
            spec, eng = _generate(model, params, prompts, k,
                                  max_new=max_new, **kw)
            assert spec == base, f"k={k} diverged from k=0"
        return eng

    def test_plain_decode(self):
        eng = self._assert_parity(REP, max_len=64, page_size=4)
        reg = eng.registry
        assert reg.get("spec.drafted").value() > 0
        assert (reg.get("spec.accepted").value()
                + reg.get("spec.rollbacks").value()) > 0

    def test_shared_prefix_and_midpage_cow(self):
        # one full shared page + mid-page divergence: adoption, CoW
        # clones and speculative writes all on the same pages
        base = [1, 2, 3, 4, 1, 2]
        prompts = [base + [3, 4, 1, 2], base + [9, 9, 1, 2],
                   base + [3, 4, 1, 9]]
        self._assert_parity(prompts, max_len=64, page_size=4)

    def test_chunked_prefill(self):
        self._assert_parity(REP, max_len=64, page_size=4,
                            prefill_chunk=4)

    @pytest.mark.slow
    def test_forced_preemption(self):
        # a pool too small for three sequences' worst-case speculative
        # spans: grows fail, victims recompute — order changes, bytes
        # must not
        self._assert_parity(REP, max_len=64, page_size=4, n_pages=13,
                            max_running=3)

    @pytest.mark.slow
    def test_eos_inside_an_accepted_draft(self):
        # eos_id equal to a drafted token: the engine must stop at the
        # accepted EOS exactly where sequential decode would
        model, params = _tiny()
        # pick an EOS the greedy continuation first emits mid-sequence,
        # so a draft can carry tokens past it that must be discarded
        base, _ = _generate(model, params, REP[:1], 0, max_new=8,
                            max_len=64, page_size=4)
        idx, eos = next(((i, t) for i, t in enumerate(base[0])
                         if i >= 1 and t not in base[0][:i]),
                        (None, None))
        if idx is None:
            pytest.skip("greedy continuation has no late-first token")
        reqs = [Request(uid=0, prompt=list(REP[0]),
                        sampling=SamplingParams(max_new_tokens=8,
                                                eos_id=int(eos)))]
        outs = []
        for k in (0, 4):
            eng = ContinuousServingEngine(
                model, params, spec_decode=k, clock=VirtualClock(),
                max_len=64, page_size=4)
            outs.append([c.tokens for c in eng.generate(reqs)])
        assert outs[0] == outs[1]
        assert outs[0][0][-1] == eos and len(outs[0][0]) == idx + 1
